"""Decoder-only transformer assembly for the dense / moe / ssm / hybrid /
vlm families.

Layer stacks use ``lax.scan`` over parameters stacked on a leading layer
axis: one layer's HLO is compiled once regardless of depth (95-layer
deepseek compiles as fast as 2-layer smoke configs), and remat wraps the
scanned body.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import (
    constrain_batch,
    constrain_gathered,
    constrain_logits,
)
from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    Params,
    cross_entropy_loss,
    dtype_of,
    embed_tokens,
    embedding_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    unembed,
)
from repro.models.moe import moe_apply, moe_init

Cache = Dict[str, jax.Array]

MOE_AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# Layer init / apply (family dispatch)
# ---------------------------------------------------------------------------

def init_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    ka, km, ks, kn = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    p: Params = {}
    if cfg.family == "ssm":
        p["norm"] = rmsnorm_init(cfg.d_model, dt)
        p["ssm"] = ssm_mod.ssm_init(ks, cfg)
        return p
    p["ln1"] = rmsnorm_init(cfg.d_model, dt)
    p["ln2"] = rmsnorm_init(cfg.d_model, dt)
    p["attn"] = attn.attention_init(ka, cfg)
    if cfg.family == "hybrid":
        p["ssm"] = ssm_mod.ssm_init(ks, cfg)
        p["norm_attn"] = rmsnorm_init(cfg.d_model, dt)
        p["norm_ssm"] = rmsnorm_init(cfg.d_model, dt)
    if cfg.is_moe:
        p["moe"] = moe_init(km, cfg)
    else:
        p["mlp"] = mlp_init(km, cfg)
    return p


def _ffn(p: Params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    if cfg.is_moe:
        return moe_apply(p["moe"], x, cfg)
    return mlp_apply(p["mlp"], x, cfg), jnp.zeros((), jnp.float32)


def layer_apply(p: Params, x: jax.Array, cfg: ModelConfig,
                ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence (train) layer. Returns (x, aux_loss).

    (An explicit per-block gather point -- constrain_gathered after each
    norm -- was tried for sequence parallelism and REFUTED: GSPMD bounced
    between layouts, adding all-to-alls and re-growing the all-reduces;
    see EXPERIMENTS.md SSPerf iteration T2.)"""
    if cfg.family == "ssm":
        h = rmsnorm(p["norm"], x, cfg.norm_eps)
        h, _ = ssm_mod.ssm_apply(p["ssm"], h, cfg)
        return x + h, jnp.zeros((), jnp.float32)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.family == "hybrid":
        a = attn.self_attention(p["attn"], h, cfg)
        s, _ = ssm_mod.ssm_apply(p["ssm"], h, cfg)
        mixed = 0.5 * (rmsnorm(p["norm_attn"], a, cfg.norm_eps)
                       + rmsnorm(p["norm_ssm"], s, cfg.norm_eps))
        x = x + mixed
    else:
        x = x + attn.self_attention(p["attn"], h, cfg)
    f, aux = _ffn(p, rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    return x + f, aux


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    ke, kl = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    return {
        "embed": embedding_init(ke, cfg),
        "layers": stacked,
        "final_norm": rmsnorm_init(cfg.d_model, dtype_of(cfg)),
    }


# ---------------------------------------------------------------------------
# Forward (train)
# ---------------------------------------------------------------------------

def _embed_inputs(params: Params, batch: Dict[str, jax.Array],
                  cfg: ModelConfig) -> jax.Array:
    x = embed_tokens(params["embed"], batch["tokens"])
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        n_p = pe.shape[1]
        x = jnp.concatenate([pe, x[:, n_p:, :]], axis=1)
    return constrain_batch(x)


def forward(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            remat: str = "full") -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, V), aux_loss)."""
    x = _embed_inputs(params, batch, cfg)

    def body(x, layer_params):
        y, aux = layer_apply(layer_params, x, cfg)
        return constrain_batch(y), aux

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "selective":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    x, auxs = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)
    return constrain_logits(logits), jnp.sum(auxs)


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            remat: str = "full") -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(params, batch, cfg, remat=remat)
    mask = batch.get("mask")
    loss = cross_entropy_loss(logits, batch["labels"], mask)
    total = loss + MOE_AUX_COEF * aux
    return total, {"ce_loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    cache: Cache = {"length": jnp.zeros((), jnp.int32)}
    if cfg.family != "ssm":
        kv = attn.init_kv_cache(cfg, batch, max_len)
        cache["k"], cache["v"] = kv["k"], kv["v"]
    if cfg.family in ("ssm", "hybrid"):
        s = ssm_mod.init_ssm_cache(cfg, batch)
        cache["conv"], cache["ssd"] = s["conv"], s["ssd"]
    return cache


def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            max_len: Optional[int] = None) -> Tuple[jax.Array, Cache]:
    """Process the prompt; returns (logits (B, S, V), filled cache)."""
    tokens = batch["tokens"]
    bsz, seq = tokens.shape
    max_len = max_len or seq
    x = _embed_inputs(params, batch, cfg)

    def body(x, layer_params):
        ys: Dict[str, jax.Array] = {}
        if cfg.family == "ssm":
            h = rmsnorm(layer_params["norm"], x, cfg.norm_eps)
            out, cache_bits = ssm_mod.ssm_apply(
                layer_params["ssm"], h, cfg, return_cache=True)
            ys["conv"], ys["ssd"] = cache_bits
            x = x + out
        else:
            h = rmsnorm(layer_params["ln1"], x, cfg.norm_eps)
            a, k, v = attn.prefill_self_attention(layer_params["attn"], h, cfg)
            pad = max_len - seq
            if pad > 0:
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            ys["k"], ys["v"] = k, v
            if cfg.family == "hybrid":
                s, cache_bits = ssm_mod.ssm_apply(
                    layer_params["ssm"], h, cfg, return_cache=True)
                ys["conv"], ys["ssd"] = cache_bits
                mixed = 0.5 * (rmsnorm(layer_params["norm_attn"], a, cfg.norm_eps)
                               + rmsnorm(layer_params["norm_ssm"], s, cfg.norm_eps))
                x = x + mixed
            else:
                x = x + a
            f, _ = _ffn(layer_params, rmsnorm(layer_params["ln2"], x, cfg.norm_eps), cfg)
            x = x + f
        return constrain_batch(x), ys

    x, ys = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)
    cache: Cache = {"length": jnp.asarray(seq, jnp.int32)}
    cache.update(ys)
    return constrain_logits(logits), cache


# ---------------------------------------------------------------------------
# Decode (one token)
# ---------------------------------------------------------------------------

def decode_step(params: Params, cache: Cache, tokens: jax.Array,
                cfg: ModelConfig) -> Tuple[jax.Array, Cache]:
    """tokens: (B,) int32. Returns (logits (B, V), updated cache)."""
    x = embed_tokens(params["embed"], tokens[:, None])
    x = constrain_batch(x)
    length = cache["length"]

    xs: Dict[str, jax.Array] = {}
    for k in ("k", "v", "conv", "ssd"):
        if k in cache:
            xs[k] = cache[k]

    def body(x, per_layer):
        layer_params, slices = per_layer
        ys: Dict[str, jax.Array] = {}
        if cfg.family == "ssm":
            h = rmsnorm(layer_params["norm"], x, cfg.norm_eps)
            out, conv_s, ssd_s = ssm_mod.ssm_decode_step(
                layer_params["ssm"], h, cfg, slices["conv"], slices["ssd"])
            ys["conv"], ys["ssd"] = conv_s, ssd_s
            x = x + out
            return x, ys
        h = rmsnorm(layer_params["ln1"], x, cfg.norm_eps)
        a, new_k, new_v = attn.decode_self_attention(
            layer_params["attn"], h, cfg, slices["k"], slices["v"], length)
        ys["k"], ys["v"] = new_k, new_v
        if cfg.family == "hybrid":
            s, conv_s, ssd_s = ssm_mod.ssm_decode_step(
                layer_params["ssm"], h, cfg, slices["conv"], slices["ssd"])
            ys["conv"], ys["ssd"] = conv_s, ssd_s
            mixed = 0.5 * (rmsnorm(layer_params["norm_attn"], a, cfg.norm_eps)
                           + rmsnorm(layer_params["norm_ssm"], s, cfg.norm_eps))
            x = x + mixed
        else:
            x = x + a
        f, _ = _ffn(layer_params, rmsnorm(layer_params["ln2"], x, cfg.norm_eps), cfg)
        return x + f, ys

    x, ys = jax.lax.scan(body, x, (params["layers"], xs))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x[:, 0, :], cfg)
    new_cache: Cache = {"length": length + 1}
    new_cache.update(ys)
    return logits, new_cache
