"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

``input_specs()`` feeds precomputed frame embeddings (B, n_frames,
d_model) straight into the encoder; the strided-conv mel frontend of the
real model is a stub per the assignment rules. The decoder is a standard
causal transformer with cross-attention into the encoder output.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import constrain_batch, constrain_logits
from repro.models import attention as attn
from repro.models.layers import (
    Params,
    cross_entropy_loss,
    dtype_of,
    embed_tokens,
    embedding_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    unembed,
)

Cache = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_enc_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    ka, km = jax.random.split(key)
    dt = dtype_of(cfg)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "ln2": rmsnorm_init(cfg.d_model, dt),
        "attn": attn.attention_init(ka, cfg),
        "mlp": mlp_init(km, cfg),
    }


def _init_dec_layer(key: jax.Array, cfg: ModelConfig) -> Params:
    ka, kc, km = jax.random.split(key, 3)
    dt = dtype_of(cfg)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "ln_cross": rmsnorm_init(cfg.d_model, dt),
        "ln2": rmsnorm_init(cfg.d_model, dt),
        "attn": attn.attention_init(ka, cfg),
        "cross": attn.attention_init(kc, cfg, cross=True),
        "mlp": mlp_init(km, cfg),
    }


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    ke, kenc, kdec = jax.random.split(key, 3)
    enc_keys = jax.random.split(kenc, cfg.encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    dt = dtype_of(cfg)
    return {
        "embed": embedding_init(ke, cfg),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "enc_norm": rmsnorm_init(cfg.d_model, dt),
        "final_norm": rmsnorm_init(cfg.d_model, dt),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(params: Params, frames: jax.Array, cfg: ModelConfig,
           remat: str = "full") -> jax.Array:
    """frames: (B, F, d_model) stub embeddings -> encoder output."""
    x = constrain_batch(frames.astype(dtype_of(cfg)))

    def body(x, p):
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        x = x + attn.self_attention(p["attn"], h, cfg, causal=False)
        f = mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        return constrain_batch(x + f), None

    if remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder: train forward
# ---------------------------------------------------------------------------

def forward(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            remat: str = "full") -> Tuple[jax.Array, jax.Array]:
    """batch: {frames (B,F,d), tokens (B,S), labels (B,S)} -> (logits, aux)."""
    enc_out = encode(params, batch["frames"], cfg, remat)
    x = constrain_batch(embed_tokens(params["embed"], batch["tokens"]))

    def body(x, p):
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        x = x + attn.self_attention(p["attn"], h, cfg, causal=True)
        hc = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        x = x + attn.cross_attention(p["cross"], hc, enc_out, cfg)
        f = mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        return constrain_batch(x + f), None

    if remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)
    return constrain_logits(logits), jnp.zeros((), jnp.float32)


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            remat: str = "full") -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(params, batch, cfg, remat)
    loss = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return loss, {"ce_loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------

def prefill(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            max_len: Optional[int] = None) -> Tuple[jax.Array, Cache]:
    """Encode frames + run the prompt through the decoder, filling caches."""
    tokens = batch["tokens"]
    bsz, seq = tokens.shape
    max_len = max_len or seq
    enc_out = encode(params, batch["frames"], cfg)
    x = constrain_batch(embed_tokens(params["embed"], tokens))

    def body(x, p):
        ys: Dict[str, jax.Array] = {}
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, k, v = attn.prefill_self_attention(p["attn"], h, cfg)
        pad = max_len - seq
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ys["k"], ys["v"] = k, v
        x = x + a
        hc = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        # cache cross-attention K/V once (computed from enc_out)
        hd = cfg.resolved_head_dim
        ck = (enc_out @ p["cross"]["wk"]).reshape(bsz, -1, cfg.n_kv_heads, hd)
        cv = (enc_out @ p["cross"]["wv"]).reshape(bsz, -1, cfg.n_kv_heads, hd)
        ys["cross_k"], ys["cross_v"] = ck, cv
        x = x + attn.cross_attention(p["cross"], hc, enc_out, cfg)
        f = mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        return constrain_batch(x + f), ys

    x, ys = jax.lax.scan(body, x, params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)
    cache: Cache = {"length": jnp.asarray(seq, jnp.int32)}
    cache.update(ys)
    return constrain_logits(logits), cache


def decode_step(params: Params, cache: Cache, tokens: jax.Array,
                cfg: ModelConfig) -> Tuple[jax.Array, Cache]:
    """tokens: (B,). Returns (logits (B, V), updated cache)."""
    x = constrain_batch(embed_tokens(params["embed"], tokens[:, None]))
    length = cache["length"]
    xs = {k: cache[k] for k in ("k", "v", "cross_k", "cross_v")}

    def body(x, per_layer):
        p, s = per_layer
        ys: Dict[str, jax.Array] = {"cross_k": s["cross_k"],
                                    "cross_v": s["cross_v"]}
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, nk, nv = attn.decode_self_attention(
            p["attn"], h, cfg, s["k"], s["v"], length)
        ys["k"], ys["v"] = nk, nv
        x = x + a
        hc = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        bsz = x.shape[0]
        hd = cfg.resolved_head_dim
        q = (hc @ p["cross"]["wq"]).reshape(bsz, 1, cfg.n_heads, hd)
        o = attn._decode_attention(q, s["cross_k"], s["cross_v"],
                                   jnp.asarray(s["cross_k"].shape[1], jnp.int32))
        x = x + o.reshape(bsz, 1, -1) @ p["cross"]["wo"]
        f = mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        return x + f, ys

    x, ys = jax.lax.scan(body, x, (params["layers"], xs))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x[:, 0, :], cfg)
    new_cache: Cache = {"length": length + 1}
    new_cache.update(ys)
    return logits, new_cache
