"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: within-chunk quadratic
(matmul-form, MXU-friendly) + across-chunk linear recurrence via
``lax.scan``. Decode is the O(1) recurrent state update. A Pallas kernel
twin of the chunked core lives in ``repro/kernels/ssd_scan``.

Projections are kept as *separate* matrices (z, x, B, C, dt) rather than
one fused ``in_proj``: the fused layout puts component boundaries at
positions that do not align with the tensor-parallel ``model`` axis, which
would force activation resharding after every slice. With split
projections, SSD heads shard cleanly over ``model`` (d_inner % model == 0)
while the small B/C/dt projections stay replicated. Single B/C group
(``ngroups=1``) as in the mamba2-2.7b config.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.layers import Params, dense_init, dtype_of

SSMState = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def ssm_init(key: jax.Array, cfg: ModelConfig) -> Params:
    dt = dtype_of(cfg)
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.ssm_n_heads
    kz, kx, kb, kc, kdt, kconv, kout = jax.random.split(key, 7)
    dt_init = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(kdt, (nh,), jnp.float32)
                * (np.log(0.1) - np.log(0.001)) + np.log(0.001))))

    def conv_w(k: jax.Array, ch: int) -> jax.Array:
        return (jax.random.normal(k, (cfg.ssm_conv, ch), jnp.float32)
                * (1.0 / np.sqrt(cfg.ssm_conv * ch))).astype(dt)

    kcx, kcb, kcc = jax.random.split(kconv, 3)
    return {
        "w_z": dense_init(kz, d, di, dt),
        "w_x": dense_init(kx, d, di, dt),
        "w_B": dense_init(kb, d, n, dt),
        "w_C": dense_init(kc, d, n, dt),
        "w_dt": dense_init(kdt, d, nh, dt),
        "conv_wx": conv_w(kcx, di),
        "conv_bx": jnp.zeros((di,), dt),
        "conv_wB": conv_w(kcb, n),
        "conv_bB": jnp.zeros((n,), dt),
        "conv_wC": conv_w(kcc, n),
        "conv_bC": jnp.zeros((n,), dt),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": dt_init,
        "norm_scale": jnp.ones((di,), dt),
        "out_proj": dense_init(kout, di, d, dt,
                               scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }


# ---------------------------------------------------------------------------
# Pieces
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NLC", "LIO", "NLC"),
        feature_group_count=x.shape[-1])
    return out + b.astype(x.dtype)


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float) -> jax.Array:
    """Mamba-2 gated RMSNorm: norm(y * silu(z)) * scale."""
    g = (y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32)))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (b, l, h, p); dt: (b, l, h) (post-softplus); A: (h,) (negative);
    B, C: (b, l, n). Returns (y (b, l, h, p), final_state (b, h, p, n)).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    L = l + pad
    nc = L // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    dA = dtc * A[None, None, None, :]                    # (b, nc, q, h) <= 0
    seg = jnp.cumsum(dA, axis=2)                         # within-chunk cumsum
    seg_last = seg[:, :, -1:, :]                         # (b, nc, 1, h)

    # ---- intra-chunk (quadratic, matmul form) ----
    G = jnp.einsum("bcqn,bckn->bcqk", Cc.astype(jnp.float32),
                   Bc.astype(jnp.float32))               # (b, nc, q, q)
    # decay(i, j) = exp(seg_i - seg_j) for i >= j
    diff = seg[:, :, :, None, :] - seg[:, :, None, :, :]  # (b, nc, q, k, h)
    ii = jnp.arange(chunk)
    tri = ii[:, None] >= ii[None, :]
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    att = G[:, :, :, :, None] * decay * dtc[:, :, None, :, :]  # (b,nc,q,k,h)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", att.astype(x.dtype), xc)

    # ---- chunk summary states ----
    decay_to_end = jnp.exp(seg_last - seg)               # (b, nc, q, h)
    weighted_x = xc * (dtc * decay_to_end)[..., None].astype(x.dtype)
    S = jnp.einsum("bcqn,bcqhp->bchpn", Bc, weighted_x)  # (b, nc, h, p, n)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(seg_last[:, :, 0, :])          # (b, nc, h)

    def step(state, inp):
        s_c, dec = inp                                   # (b,h,p,n), (b,h)
        prior = state
        state = dec[..., None, None] * state + s_c.astype(jnp.float32)
        return state, prior

    state0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))
    S_t = jnp.moveaxis(S, 1, 0)                          # (nc, b, h, p, n)
    dec_t = jnp.moveaxis(chunk_decay, 1, 0)              # (nc, b, h)
    final_state, priors = jax.lax.scan(step, state0, (S_t, dec_t))
    prior_states = jnp.moveaxis(priors, 0, 1)            # (b, nc, h, p, n)

    # ---- inter-chunk contribution ----
    Cdec = (Cc[:, :, :, None, :].astype(jnp.float32)
            * jnp.exp(seg)[..., None])                   # (b, nc, q, h, n)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp",
                         Cdec.astype(x.dtype), prior_states.astype(x.dtype))
    y = (y_intra + y_inter).reshape(b, L, h, p)[:, :l]
    return y, final_state.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixer: full-sequence apply (train / prefill)
# ---------------------------------------------------------------------------

def ssm_apply(params: Params, u: jax.Array, cfg: ModelConfig,
              init_state: Optional[jax.Array] = None,
              return_cache: bool = False):
    """u: (B, L, d_model) -> (out, final_state) or, with ``return_cache``,
    (out, (conv_cache (B, K-1, di+2n), ssd_state (B, nh, p, n)))."""
    bsz, l, _ = u.shape
    di, n, nh, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_head_dim
    z = u @ params["w_z"]
    xr_raw = u @ params["w_x"]
    Br_raw = u @ params["w_B"]
    Cr_raw = u @ params["w_C"]
    dt_raw = u @ params["w_dt"]
    xr = jax.nn.silu(_causal_conv(xr_raw, params["conv_wx"], params["conv_bx"]))
    Bm = jax.nn.silu(_causal_conv(Br_raw, params["conv_wB"], params["conv_bB"]))
    Cm = jax.nn.silu(_causal_conv(Cr_raw, params["conv_wC"], params["conv_bC"]))
    xs = xr.reshape(bsz, l, nh, p)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    y, state = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk, init_state)
    y = y + xs * params["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, l, di)
    y = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if not return_cache:
        return out, state
    # conv cache = last K-1 *pre-conv* rows (what decode's window expects)
    k = cfg.ssm_conv
    raw = jnp.concatenate([xr_raw, Br_raw, Cr_raw], axis=-1)  # (B, L, di+2n)
    if l >= k - 1:
        tail = raw[:, l - (k - 1):, :]
    else:
        tail = jnp.pad(raw, ((0, 0), (k - 1 - l, 0), (0, 0)))
    return out, (tail, state)


# ---------------------------------------------------------------------------
# Decode: O(1) recurrent update
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg: ModelConfig, batch: int,
                   n_layers: Optional[int] = None) -> SSMState:
    dt = dtype_of(cfg)
    L = n_layers if n_layers is not None else cfg.n_layers
    di, n = cfg.d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, di + 2 * n), dt),
        "ssd": jnp.zeros((L, batch, cfg.ssm_n_heads, cfg.ssm_head_dim, n), dt),
    }


def ssm_decode_step(params: Params, u: jax.Array, cfg: ModelConfig,
                    conv_state: jax.Array, ssd_state: jax.Array,
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. u: (B, 1, d). conv_state: (B, K-1, di+2n);
    ssd_state: (B, nh, p, n). Returns (out, conv_state, ssd_state)."""
    bsz = u.shape[0]
    di, n, nh, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads, cfg.ssm_head_dim
    ut = u[:, 0, :]
    z = ut @ params["w_z"]
    xr = ut @ params["w_x"]
    Br = ut @ params["w_B"]
    Cr = ut @ params["w_C"]
    dt_raw = ut @ params["w_dt"]

    new_in = jnp.concatenate([xr, Br, Cr], axis=-1)       # (B, di+2n)
    window = jnp.concatenate([conv_state, new_in[:, None, :]], axis=1)
    conv_w = jnp.concatenate(
        [params["conv_wx"], params["conv_wB"], params["conv_wC"]], axis=-1)
    conv_b = jnp.concatenate(
        [params["conv_bx"], params["conv_bB"], params["conv_bC"]], axis=-1)
    conv_out = jnp.einsum("bkc,kc->bc", window, conv_w.astype(u.dtype))
    mixed = jax.nn.silu(conv_out + conv_b.astype(u.dtype))
    new_conv_state = window[:, 1:, :]
    xs = mixed[..., :di].reshape(bsz, nh, p)
    Bm = mixed[..., di:di + n]
    Cm = mixed[..., di + n:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, :])
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])                                   # (B, nh)
    upd = (dt[..., None] * xs.astype(jnp.float32))[..., :, None] \
        * Bm.astype(jnp.float32)[:, None, None, :]                  # (B,nh,p,n)
    state = (dA[..., None, None] * ssd_state.astype(jnp.float32) + upd)
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * params["D"][None, :, None]
    y = y.reshape(bsz, di).astype(u.dtype)
    y = _gated_norm(y, z, params["norm_scale"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None, :]
    return out, new_conv_state.astype(conv_state.dtype), state.astype(ssd_state.dtype)
