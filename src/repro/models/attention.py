"""GQA attention: training (full / blockwise-causal), prefill, and decode.

Three execution paths share one set of weights:

* ``full``      -- materialized-scores attention for short sequences.
* ``blockwise`` -- exact-causal blocked online-softmax attention. The
  lower-triangular (q_block, kv_block) pairs are enumerated *statically*
  and walked with one ``lax.scan``, so the compiled FLOPs equal the true
  causal cost (no masked upper-triangle waste) and no (S, S) score tensor
  is ever materialized. This is the pure-JAX structural twin of the
  Pallas ``flash_attn`` kernel (used on real TPUs; see repro/kernels).
* ``decode``    -- one-token attention against a KV cache.

All paths accumulate softmax statistics in fp32.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.layers import (
    Params,
    apply_rope,
    dense_init,
    dtype_of,
    head_rmsnorm,
)

NEG_INF = -1e30

# Sequence length above which the blockwise path is used (module-level so
# perf iterations can force the flash/blockwise path at shorter contexts;
# see benchmarks/hillclimb.py).
BLOCKWISE_THRESHOLD = 4096
Q_BLOCK = 512
KV_BLOCK = 512


def set_blockwise_threshold(n: int) -> None:
    global BLOCKWISE_THRESHOLD
    BLOCKWISE_THRESHOLD = n


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def attention_init(key: jax.Array, cfg: ModelConfig,
                   cross: bool = False) -> Params:
    dt = dtype_of(cfg)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    p = {
        "wq": dense_init(kq, d, cfg.n_heads * hd, dt),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ko, cfg.n_heads * hd, d, dt,
                         scale=1.0 / np.sqrt(2 * cfg.n_layers)),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _project_qkv(params: Params, xq: jax.Array, xkv: jax.Array,
                 cfg: ModelConfig, q_positions: Optional[jax.Array],
                 kv_positions: Optional[jax.Array],
                 use_rope: bool) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Project to (B, S, H, hd) / (B, Skv, K, hd) and apply qk-norm + RoPE."""
    hd = cfg.resolved_head_dim
    b, sq, _ = xq.shape
    skv = xkv.shape[1]
    q = (xq @ params["wq"]).reshape(b, sq, cfg.n_heads, hd)
    k = (xkv @ params["wk"]).reshape(b, skv, cfg.n_kv_heads, hd)
    v = (xkv @ params["wv"]).reshape(b, skv, cfg.n_kv_heads, hd)
    if "q_norm" in params:
        q = head_rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = head_rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, q_positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, K, hd) -> (B, S, H, hd) by repeating each KV head."""
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


# ---------------------------------------------------------------------------
# Full (materialized scores) attention
# ---------------------------------------------------------------------------

def _full_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool) -> jax.Array:
    b, sq, h, hd = q.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        skv = k.shape[1]
        qi = jnp.arange(sq)[:, None] + (skv - sq)
        ki = jnp.arange(skv)[None, :]
        scores = jnp.where(ki <= qi, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# ---------------------------------------------------------------------------
# Blockwise exact-causal attention (static lower-triangle pair walk)
# ---------------------------------------------------------------------------

def _blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         causal: bool, q_block: int = Q_BLOCK,
                         kv_block: int = KV_BLOCK) -> jax.Array:
    """Exact blocked online-softmax attention without materializing (S, S).

    Enumerates the needed (q_block, kv_block) pairs statically (the lower
    triangle when causal, the full grid otherwise) and walks them with one
    ``lax.scan`` carrying per-q-block accumulators (acc, m, l). Compiled
    FLOP count equals the exact attention cost.
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    nq = -(-sq // q_block)
    nk = -(-skv // kv_block)
    pad_q = nq * q_block - sq
    pad_k = nk * kv_block - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qb = q.reshape(b, nq, q_block, h, hd)
    kb = k.reshape(b, nk, kv_block, h, hd)
    vb = v.reshape(b, nk, kv_block, h, hd)

    # Static pair enumeration. With equal block sizes and right-aligned
    # causal offset, q block i may attend kv block j iff the block's first
    # query position >= the block's first key position boundary.
    offset = skv - sq  # decode-style right alignment (0 for self-attn train)
    pairs = []
    for i in range(nq):
        for j in range(nk):
            if not causal:
                pairs.append((i, j))
                continue
            q_lo = i * q_block + offset          # first absolute q position
            k_lo = j * kv_block                  # first key position in block
            if k_lo <= q_lo + q_block - 1:       # block intersects allowed region
                pairs.append((i, j))
    pair_arr = jnp.asarray(np.array(pairs, dtype=np.int32))  # (P, 2)

    scale = 1.0 / np.sqrt(hd)
    q_pos = jnp.arange(nq * q_block) + offset
    k_pos = jnp.arange(nk * kv_block)

    def body(carry, pair):
        acc, m, l = carry           # acc: (b, nq, q_block, h, hd) fp32
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_index_in_dim(qb, i, axis=1, keepdims=False)
        ki = jax.lax.dynamic_index_in_dim(kb, j, axis=1, keepdims=False)
        vi = jax.lax.dynamic_index_in_dim(vb, j, axis=1, keepdims=False)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, ki).astype(jnp.float32) * scale
        qp = jax.lax.dynamic_slice_in_dim(q_pos, i * q_block, q_block)
        kp = jax.lax.dynamic_slice_in_dim(k_pos, j * kv_block, kv_block)
        mask = kp[None, :] <= qp[:, None] if causal else None
        # also mask kv padding
        kv_valid = kp < skv
        valid = kv_valid[None, :] if mask is None else (mask & kv_valid[None, :])
        s = jnp.where(valid[None, None], s, NEG_INF)
        m_blk = jnp.max(s, axis=-1)                       # (b, h, q_block)
        m_old = jax.lax.dynamic_index_in_dim(m, i, axis=1, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, i, axis=1, keepdims=False)
        acc_old = jax.lax.dynamic_index_in_dim(acc, i, axis=1, keepdims=False)
        m_new = jnp.maximum(m_old, jnp.transpose(m_blk, (0, 2, 1)))  # (b,q,h)
        p = jnp.exp(s - jnp.transpose(m_new, (0, 2, 1))[:, :, :, None])
        corr = jnp.exp(m_old - m_new)                     # (b, q, h)
        l_new = l_old * corr + jnp.transpose(jnp.sum(p, axis=-1), (0, 2, 1))
        pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vi.dtype), vi)
        acc_new = acc_old * corr[..., None] + pv.astype(jnp.float32)
        acc = jax.lax.dynamic_update_index_in_dim(acc, acc_new, i, axis=1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, axis=1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, axis=1)
        return (acc, m, l), None

    acc0 = jnp.zeros((b, nq, q_block, h, hd), jnp.float32)
    m0 = jnp.full((b, nq, q_block, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nq, q_block, h), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), pair_arr)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(b, nq * q_block, h, hd)[:, :sq]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (one new token vs. a KV cache)
# ---------------------------------------------------------------------------

def _decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                      cache_len: jax.Array) -> jax.Array:
    """q: (B, 1, H, hd); caches: (B, S, K, hd); cache_len: () or (B,).

    GQA is handled with a grouped einsum against the *unexpanded* cache:
    materializing the repeated KV (jnp.repeat) would multiply the
    decode-step HBM traffic by H/K (6x for grok) -- decode is
    memory-bound, so this is the hot path's dominant cost."""
    b, _, h, hd = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qg = q.reshape(b, 1, kh, g, hd)
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                        k_cache).astype(jnp.float32) * scale
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))   # (B or 1, S)
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return out.reshape(b, 1, h, hd)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def n_pair_scan_lengths(cfg, shape) -> frozenset:
    """Trip counts of the blockwise-attention pair scans a given
    (arch, shape) cell lowers -- used by flash-kernel cost accounting
    (launch/costing.py) to mark those scans VMEM-resident."""
    out = set()
    seqs = [shape.seq_len]
    if cfg.is_encdec:
        seqs.append(cfg.n_frames)
    for s in seqs:
        if s <= BLOCKWISE_THRESHOLD:
            continue
        nq = -(-s // Q_BLOCK)
        nk = -(-s // KV_BLOCK)
        # causal lower-triangle count (self-attn; offset 0)
        causal_pairs = sum(min(i + 1, nk) for i in range(nq))
        out.add(causal_pairs)
        out.add(nq * nk)        # non-causal (encoder) variant
    return frozenset(out)


def self_attention(params: Params, x: jax.Array, cfg: ModelConfig,
                   causal: bool = True,
                   positions: Optional[jax.Array] = None,
                   use_rope: bool = True,
                   force_blockwise: Optional[bool] = None) -> jax.Array:
    """Training/prefill self-attention over (B, S, d_model)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, x, x, cfg, positions, positions, use_rope)
    use_blockwise = (s > BLOCKWISE_THRESHOLD if force_blockwise is None
                     else force_blockwise)  # noqa: F823 (module global)
    if use_blockwise:
        o = _blockwise_attention(q, k, v, causal)
    else:
        o = _full_attention(q, k, v, causal)
    return o.reshape(b, s, -1) @ params["wo"]


def cross_attention(params: Params, x: jax.Array, ctx: jax.Array,
                    cfg: ModelConfig) -> jax.Array:
    """Decoder->encoder cross-attention (no mask, no RoPE)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, ctx, cfg, None, None, use_rope=False)
    o = _full_attention(q, k, v, causal=False)
    return o.reshape(b, s, -1) @ params["wo"]


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: Optional[int] = None) -> Dict[str, jax.Array]:
    dt = dtype_of(cfg)
    L = n_layers if n_layers is not None else cfg.n_layers
    hd = cfg.resolved_head_dim
    shape = (L, batch, max_len, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "length": jnp.zeros((), jnp.int32),
    }


def decode_self_attention(params: Params, x: jax.Array, cfg: ModelConfig,
                          k_cache: jax.Array, v_cache: jax.Array,
                          cache_len: jax.Array,
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B, 1, d). Returns (out, new_k_entry, new_v_entry).

    The caller owns cache insertion (so the layer scan can batch the
    dynamic_update_slice across layers).
    """
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.reshape(cache_len, (1, 1)), (b, 1))
    q, k_new, v_new = _project_qkv(params, x, x, cfg, pos, pos, use_rope=True)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), cache_len, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), cache_len, axis=1)
    o = _decode_attention(q, k_cache, v_cache, cache_len + 1)
    out = o.reshape(b, 1, -1) @ params["wo"]
    return out, k_cache, v_cache


def prefill_self_attention(params: Params, x: jax.Array, cfg: ModelConfig,
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill: causal attention returning output and the K/V to cache."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, x, x, cfg, positions, positions, True)
    if s > BLOCKWISE_THRESHOLD:
        o = _blockwise_attention(q, k, v, causal=True)
    else:
        o = _full_attention(q, k, v, causal=True)
    out = o.reshape(b, s, -1) @ params["wo"]
    return out, k, v
